"""Serving driver: batched prefill + token-by-token decode.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --reduced \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced as make_reduced
from repro.data.pipeline import SyntheticTextDataset
from repro.models.model import decode_step, init_cache, init_params, prefill


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = make_reduced(cfg)
    cfg = cfg.replace(dtype="float32")

    key = jax.random.PRNGKey(args.seed)
    params = init_params(key, cfg)
    ds = SyntheticTextDataset(vocab_size=cfg.vocab_size, seed=args.seed)
    prompts = np.stack(
        [ds.tokens(args.prompt_len, seed=s) for s in range(args.batch)]
    )
    total = args.prompt_len + args.gen
    cache = init_cache(cfg, args.batch, total + cfg.num_patches)

    kw = {}
    if cfg.encoder_layers:
        kw["frames"] = jnp.zeros((args.batch, cfg.encoder_seq, cfg.d_model),
                                 jnp.dtype(cfg.dtype))
    if cfg.num_patches:
        kw["patches"] = jnp.zeros((args.batch, cfg.num_patches, cfg.d_model),
                                  jnp.dtype(cfg.dtype))

    t0 = time.perf_counter()
    pf = jax.jit(lambda p, t, c: prefill(p, cfg, t, c, **kw))
    logits, cache = pf(params, jnp.asarray(prompts), cache)
    t_prefill = time.perf_counter() - t0

    dec = jax.jit(lambda p, tok, c, pos: decode_step(p, cfg, tok, c, pos))
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    generated = [tok]
    t0 = time.perf_counter()
    offset = cfg.num_patches  # visual prefix occupies the cache head
    for i in range(args.gen - 1):
        logits, cache = dec(params, tok, cache, jnp.int32(offset + args.prompt_len + i))
        if args.temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, logits[:, -1] / args.temperature)[:, None]
            tok = tok.astype(jnp.int32)
        else:
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        generated.append(tok)
    gen = np.concatenate([np.asarray(g) for g in generated], axis=1)
    t_decode = time.perf_counter() - t0
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len} gen={args.gen}")
    print(f"prefill {t_prefill*1e3:.1f} ms; decode {t_decode*1e3/max(1,args.gen-1):.1f} ms/token")
    for i in range(min(2, args.batch)):
        print(f"  seq{i}: prompt={prompts[i][:8].tolist()}… generated={gen[i].tolist()}")


if __name__ == "__main__":
    main()
