import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch × input shape × mesh).

Proves the distribution config is coherent without hardware: the production
mesh is built from 512 host-platform placeholder devices, every step function
is lowered from ShapeDtypeStructs (no allocation), compiled, and its
memory_analysis / cost_analysis / collective schedule are captured for the
roofline (§Roofline in EXPERIMENTS.md).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--json out.json]
"""

import argparse
import json
import sys
import time
import traceback
from typing import Any, Dict

import jax

from repro.configs.base import INPUT_SHAPES
from repro.configs.registry import ARCHS, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.workloads import make_workload, supported
from repro.utils.hlo import (
    collective_bytes,
    cost_analysis_dict,
    loop_aware_collective_bytes,
    peak_memory_bytes,
)
from repro.utils.roofline import roofline_terms


def dryrun_one(
    arch: str, shape_name: str, *, multi_pod: bool = False, verbose: bool = True
) -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = next(s for s in INPUT_SHAPES if s.name == shape_name)
    ok, why = supported(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "status": "skipped", "why": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    t0 = time.perf_counter()
    wl = make_workload(cfg, shape_name, mesh, multi_pod=multi_pod)
    with mesh:
        lowered = jax.jit(
            wl["fn"],
            in_shardings=wl["in_shardings"],
            out_shardings=wl["out_shardings"],
        ).lower(*wl["args"])
        t_lower = time.perf_counter() - t0
        t0 = time.perf_counter()
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0

    mem = compiled.memory_analysis()
    cost = cost_analysis_dict(compiled)
    hlo_text = compiled.as_text()
    coll = collective_bytes(hlo_text)
    coll_corrected = loop_aware_collective_bytes(hlo_text)
    res = {
        "arch": arch,
        "shape": shape_name,
        "kind": wl["kind"],
        "status": "ok",
        "chips": int(n_chips),
        "multi_pod": multi_pod,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes_per_device": int(mem.argument_size_in_bytes),
            "output_bytes_per_device": int(mem.output_size_in_bytes),
            "temp_bytes_per_device": int(mem.temp_size_in_bytes),
            "peak_bytes_per_device": peak_memory_bytes(mem),
        },
        "cost": {
            "flops": float(cost.get("flops", 0.0)),
            "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        },
        "collectives": coll,
        "collectives_corrected": coll_corrected,
    }
    res["roofline"] = roofline_terms(cfg, shape, res, chips=n_chips)
    if verbose:
        m = res["memory"]
        r = res["roofline"]
        print(
            f"[ok] {arch} × {shape_name} ({'2-pod' if multi_pod else '1-pod'}, "
            f"{n_chips} chips) compile={t_compile:.0f}s "
            f"peak/dev={m['peak_bytes_per_device']/2**30:.2f}GiB "
            f"args/dev={m['argument_bytes_per_device']/2**30:.2f}GiB "
            f"compute={r['compute_s']:.2e}s memory={r['memory_s']:.2e}s "
            f"collective={r['collective_s']:.2e}s → {r['bottleneck']}"
        )
        sys.stdout.flush()
    return res


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--json", default=None)
    args = ap.parse_args()

    combos = []
    archs = sorted(ARCHS) if (args.all or not args.arch) else [args.arch]
    shapes = [s.name for s in INPUT_SHAPES] if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for mp in meshes:
        for a in archs:
            for s in shapes:
                combos.append((a, s, mp))

    results = []
    for a, s, mp in combos:
        try:
            results.append(dryrun_one(a, s, multi_pod=mp))
        except Exception as e:  # a failure here is a bug in the system
            traceback.print_exc()
            results.append(
                {"arch": a, "shape": s, "multi_pod": mp, "status": "error",
                 "error": f"{type(e).__name__}: {e}"}
            )
        if results[-1]["status"] == "skipped":
            print(f"[skip] {a} × {s}: {results[-1]['why']}")

    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"\ndry-run: {n_ok} ok, {n_skip} skipped (documented), {n_err} errors")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=1)
        print(f"wrote {args.json}")
    if n_err:
        sys.exit(1)


if __name__ == "__main__":
    main()
