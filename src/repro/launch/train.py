"""End-to-end LM training driver.

Runs a real training loop on local devices (CPU here, TPU identically):
builds the model from ``--arch`` (optionally the reduced variant), the
synthetic data pipeline, AdamW + cosine schedule, periodic eval + checkpoints.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --reduced \
      --steps 200 --batch 8 --seq-len 128
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.configs import TrainConfig, get_config, reduced as make_reduced
from repro.data.pipeline import SyntheticTextDataset, make_batches
from repro.train.step import init_train_state, make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true",
                    help="train the reduced smoke variant (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ce-chunk", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--dtype", default="float32")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = make_reduced(cfg)
    cfg = cfg.replace(dtype=args.dtype)
    tcfg = TrainConfig(
        global_batch=args.batch,
        seq_len=args.seq_len,
        microbatches=args.microbatches,
        ce_chunk=args.ce_chunk,
        learning_rate=args.lr,
        warmup_steps=max(1, args.steps // 20),
        total_steps=args.steps,
        seed=args.seed,
    )
    n_params_note = cfg.param_count()
    print(f"arch={cfg.name} params≈{n_params_note/1e6:.1f}M "
          f"(active {cfg.active_param_count()/1e6:.1f}M) dtype={cfg.dtype}")

    state = init_train_state(jax.random.PRNGKey(args.seed), cfg)
    step = jax.jit(make_train_step(cfg, tcfg))
    ds = SyntheticTextDataset(vocab_size=cfg.vocab_size, seed=args.seed)

    def extras(b):
        out = {}
        if cfg.encoder_layers:
            rng = np.random.default_rng(args.seed)
            out["frames"] = jnp.asarray(
                rng.normal(0, 1, (args.batch, cfg.encoder_seq, cfg.d_model)),
                jnp.dtype(cfg.dtype),
            )
        if cfg.num_patches:
            rng = np.random.default_rng(args.seed + 1)
            out["patches"] = jnp.asarray(
                rng.normal(0, 1, (args.batch, cfg.num_patches, cfg.d_model)),
                jnp.dtype(cfg.dtype),
            )
        return out

    t0 = time.perf_counter()
    losses = []
    for i, batch in enumerate(
        make_batches(ds, batch=args.batch, seq_len=args.seq_len, steps=args.steps)
    ):
        b = {k: jnp.asarray(v) for k, v in batch.items()}
        b.update(extras(b))
        state, metrics = step(state, b)
        losses.append(float(metrics["loss"]))
        if (i + 1) % args.log_every == 0:
            rate = args.batch * args.seq_len * args.log_every / (time.perf_counter() - t0)
            t0 = time.perf_counter()
            print(f"step {i+1:5d} loss={losses[-1]:.4f} "
                  f"lr={float(metrics['lr']):.2e} tok/s={rate:,.0f}")
    print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f})")
    if args.checkpoint:
        save_checkpoint(args.checkpoint, state.params,
                        metadata={"arch": cfg.name, "steps": args.steps})
        print(f"saved {args.checkpoint}")


if __name__ == "__main__":
    main()
