"""Mesh-mapped FKGE: the paper's peer-to-peer topology on one (simulated) pod.

Two KG owners live on two mesh slices; the PPAT exchange runs as an SPMD
program where the ONLY cross-slice tensors are the generated embeddings and
their gradients (collective-permute = the paper's pipes). The entity tables
are sharded over the 'model' axis via the sharded KGE train step.

  PYTHONPATH=src python examples/distributed_fkge.py
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 " + os.environ.get("XLA_FLAGS", "")
)

import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.distributed import (
    init_distributed_ppat,
    make_party_mesh,
    make_sharded_kge_step,
    ppat_exchange_step,
)
from repro.core.ppat import PPATConfig
from repro.core.alignment import csls_retrieval_acc, procrustes
from repro.kge.data import corrupt_triples, synthesize_universe
from repro.kge.models import KGEModel, init_kge


def main():
    print(f"devices: {len(jax.devices())}")
    kgs = synthesize_universe(
        seed=0, scale=1 / 400,
        kg_stats=[("A", 10, 90000, 300000), ("B", 8, 70000, 240000)],
        alignments=[("A", "B", 30000)],
    )
    a, b = kgs["A"], kgs["B"]
    ia, ib = a.aligned_with(b)
    print(f"A: {a.num_entities} ents; B: {b.num_entities} ents; aligned: {len(ia)}")

    # ---- sharded local KGE training (entity tables over 'model') ----------
    mesh_kge = jax.make_mesh((2, 4), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
    dim = 32
    rng = np.random.default_rng(0)
    params = {}
    for name, kg in (("A", a), ("B", b)):
        # pad the entity table to a mesh-divisible row count (vocab-padding
        # pattern; padded rows never appear in triples)
        e_pad = -(-kg.num_entities // 8) * 8
        model = KGEModel("transe", e_pad, kg.num_relations, dim, margin=2.0)
        p = init_kge(jax.random.PRNGKey(hash(name) % 2**31), model)
        step = make_sharded_kge_step(mesh_kge, model, lr=0.3)
        t0 = time.time()
        for _ in range(300):
            batch = kg.train[rng.integers(0, len(kg.train), 128)]
            neg = corrupt_triples(rng, batch, kg.num_entities)
            p, loss = step(p, jnp.asarray(batch), jnp.asarray(neg))
        print(f"{name}: sharded KGE 300 steps, loss={float(loss):.3f} "
              f"({time.time()-t0:.1f}s)")
        params[name] = p

    # ---- PPAT over the party mesh (client slice ↔ host slice) -------------
    # pull aligned rows off the KGE mesh (the "export" the paper's owners do)
    x = jnp.asarray(np.asarray(params["A"]["ent"])[ia])
    y = jnp.asarray(np.asarray(params["B"]["ent"])[ib])
    cfg = PPATConfig(steps=120, seed=0)
    mesh = make_party_mesh(2)
    state = init_distributed_ppat(jax.random.PRNGKey(0), dim, cfg)
    step = ppat_exchange_step(mesh, cfg)
    key = jax.random.PRNGKey(1)
    t0 = time.time()
    for i in range(cfg.steps):
        xi = rng.integers(0, len(x), cfg.batch)
        yi = rng.integers(0, len(y), cfg.batch)
        xb = jnp.stack([x[xi], jnp.zeros((cfg.batch, dim))])  # party0 = client
        yb = jnp.stack([jnp.zeros((cfg.batch, dim)), y[yi]])  # party1 = host
        keys = jax.random.split(jax.random.fold_in(key, i), 2)
        state, metrics, (n0, n1) = step(state, xb, yb, keys)
    print(f"PPAT (SPMD, collective-permute exchange): {cfg.steps} rounds "
          f"in {time.time()-t0:.1f}s; host gen_loss={float(metrics['gen_loss'][1]):.3f}")

    synth = x @ state["w"]
    r = procrustes(synth, y)  # host-local refinement
    acc = csls_retrieval_acc(synth @ r, y)
    print(f"CSLS retrieval of refined DP embeddings vs host: {acc*100:.1f}%")
    txt = step.lower(state, xb, yb, keys).compile().as_text()
    n_cp = txt.count("collective-permute(") + txt.count("collective-permute-start(")
    print(f"collective-permutes in the lowered exchange program: {n_cp} "
          f"(the paper's pipe sends, §4.4: ≤0.845 Mb per batch)")


if __name__ == "__main__":
    main()
