"""The paper's full experiment, scaled: 11 LOD-statistics-matched KGs,
mixed base models (TransE/H/R/D as in Fig. 5), asynchronous federation with
handshake + backtrack + broadcast.

  PYTHONPATH=src python examples/federated_11kg.py [--ticks 4] [--scale 400]
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

from repro.core.federation import FederationScheduler
from repro.core.ppat import PPATConfig
from repro.kge.data import synthesize_universe


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--ticks", type=int, default=3)
    ap.add_argument("--scale", type=float, default=400.0, help="1/scale of Tab. 2")
    ap.add_argument("--dim", type=int, default=32)
    ap.add_argument("--ppat-steps", type=int, default=100)
    args = ap.parse_args()

    t0 = time.time()
    kgs = synthesize_universe(seed=0, scale=1 / args.scale)
    print(f"generated {len(kgs)} KGs in {time.time()-t0:.1f}s "
          f"({sum(len(k.triples) for k in kgs.values())} triples total)")

    # Fig. 5: each KG randomly picks a translation-family base model
    families = {}
    fams = ["transe", "transh", "transr", "transd"]
    for i, name in enumerate(kgs):
        families[name] = fams[i % len(fams)]
    print("base models:", families)

    fed = FederationScheduler(
        kgs,
        families=families,
        dim=args.dim,
        ppat_cfg=PPATConfig(steps=args.ppat_steps, seed=0),
        local_epochs=100,
        update_epochs=30,
        seed=0,
    )
    init = fed.initial_training()
    print("\ninitial  :", {k: round(v, 3) for k, v in sorted(init.items())})
    final = fed.run(max_ticks=args.ticks)
    print("federated:", {k: round(v, 3) for k, v in sorted(final.items())})

    gains = {k: final[k] - init[k] for k in final}
    print("gains    :", {k: f"{v*100:+.1f}%" for k, v in sorted(gains.items())})
    n_acc = sum(1 for e in fed.events if e.kind == "ppat" and e.accepted)
    n_all = sum(1 for e in fed.events if e.kind == "ppat")
    print(f"\n{n_all} handshakes, {n_acc} accepted, "
          f"{len([e for e in fed.events if e.kind=='self-train'])} self-train rounds, "
          f"max ε̂ = {max(fed.epsilons):.2f}, total {time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()
