"""Serving example: continuous batching engine over a reduced model.

Submits a burst of ragged-length requests into a small slot pool and drains
them, printing per-request latency — demonstrates the serving substrate the
decode dry-run shapes model.

  PYTHONPATH=src python examples/serve_engine.py [--arch mamba2-2.7b]
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.models.model import init_params
from repro.serving.engine import ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=3)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch)).replace(dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(params, cfg, max_batch=args.slots, max_len=128)

    rng = np.random.default_rng(0)
    t0 = time.time()
    for i in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size, 8 + 4 * (i % 3)).astype(np.int32)
        eng.submit(prompt, max_new_tokens=8 + (i % 2) * 4)
    done = eng.run_until_drained()
    wall = time.time() - t0

    print(f"arch={cfg.name} slots={args.slots} requests={args.requests}")
    for r in sorted(done, key=lambda r: r.rid):
        lat = (r.finished_at - r.submitted_at) * 1e3
        print(f"  req{r.rid}: prompt={len(r.prompt):3d} gen={len(r.generated):3d} "
              f"latency={lat:7.1f} ms  tokens={r.generated[:6]}…")
    total_tokens = sum(len(r.generated) for r in done)
    print(f"drained {total_tokens} tokens in {wall:.2f}s "
          f"({total_tokens/wall:.1f} tok/s aggregate)")


if __name__ == "__main__":
    main()
