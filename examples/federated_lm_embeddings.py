"""FKGE applied to the assigned LLM architectures (DESIGN.md §4).

Two parties train reduced LMs from different corpora with an overlapping
vocabulary (aligned token ids = the paper's aligned entities). The parties
run PPAT over the shared rows of their token-embedding tables; the host
aggregates the DP-synthesized embeddings and continues training. This is the
technique transplanted verbatim onto the transformer substrate — only the
"KG embedding table" becomes the "token embedding table".

  PYTHONPATH=src python examples/federated_lm_embeddings.py [--arch qwen3-0.6b]
"""
import argparse
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import TrainConfig, get_config, reduced
from repro.core.alignment import procrustes
from repro.core.ppat import PPATConfig, train_ppat
from repro.data.pipeline import SyntheticTextDataset, make_batches
from repro.train.step import init_train_state, make_train_step


def train_party(cfg, seed, steps, batch=8, seq=64):
    tcfg = TrainConfig(global_batch=batch, seq_len=seq, learning_rate=3e-3,
                       warmup_steps=5, total_steps=steps)
    state = init_train_state(jax.random.PRNGKey(seed), cfg)
    step = jax.jit(make_train_step(cfg, tcfg))
    ds = SyntheticTextDataset(vocab_size=cfg.vocab_size, seed=seed)
    loss = None
    for b in make_batches(ds, batch=batch, seq_len=seq, steps=steps, seed=seed):
        state, m = step(state, {k: jnp.asarray(v) for k, v in b.items()})
        loss = float(m["loss"])
    return state, step, ds, loss


def eval_loss(cfg, state, ds, seed=99, batches=5, batch=8, seq=64):
    from repro.train.loss import lm_loss

    total = 0.0
    for i, b in enumerate(make_batches(ds, batch=batch, seq_len=seq,
                                       steps=batches, seed=seed)):
        l, _ = lm_loss(state.params, cfg, jnp.asarray(b["tokens"]),
                       jnp.asarray(b["labels"]))
        total += float(l)
    return total / batches


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--steps", type=int, default=60)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch)).replace(dtype="float32")
    print(f"arch family: {cfg.name} (reduced: {cfg.num_layers}L d={cfg.d_model})")

    # party A and party B: same vocab (fully aligned token ids), different data
    state_a, _, ds_a, loss_a = train_party(cfg, seed=0, steps=args.steps)
    state_b, step_b, ds_b, loss_b = train_party(cfg, seed=1, steps=args.steps)
    print(f"local training: A loss={loss_a:.3f}  B loss={loss_b:.3f}")

    # aligned rows: the shared head of the vocab (most frequent tokens)
    n_aligned = min(256, cfg.vocab_size)
    idx = jnp.arange(n_aligned)
    x = state_a.params["embed"]["table"][idx].astype(jnp.float32)  # client: A
    y = state_b.params["embed"]["table"][idx].astype(jnp.float32)  # host:   B

    client, host, hist = train_ppat(x, y, PPATConfig(steps=150, seed=0))
    synth = client.generate(x)
    r = procrustes(synth, y)  # host-local MUSE refinement (DP post-processing)
    refined = synth @ r
    print(f"PPAT done: ε̂={hist['epsilon']:.2f} "
          f"(λ={0.05}, δ=1e-5; only G(X) and ∂L/∂G(X) crossed the boundary)")

    before = eval_loss(cfg, state_b, ds_b)
    new_table = state_b.params["embed"]["table"].at[idx].set(
        (0.5 * (y + refined)).astype(state_b.params["embed"]["table"].dtype)
    )
    params_new = dict(state_b.params, embed={"table": new_table})
    state_new = state_b._replace(params=params_new)
    # KGEmb-Update: brief local retraining after aggregation
    for b in make_batches(ds_b, batch=8, seq_len=64, steps=10, seed=42):
        state_new, _ = step_b(state_new, {k: jnp.asarray(v) for k, v in b.items()})
    after = eval_loss(cfg, state_new, ds_b)
    verdict = "kept" if after <= before else "backtracked (paper's rule)"
    print(f"host eval loss: {before:.3f} → {after:.3f} → {verdict}")


if __name__ == "__main__":
    main()
