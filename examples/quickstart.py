"""Quickstart: federate two knowledge graphs with FKGE in ~a minute on CPU.

Builds two synthetic KGs sharing aligned entities, trains each locally
(TransE), runs one PPAT federation round in each direction, and prints the
triple-classification scores before/after plus the DP budget ε̂.

  PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

from repro.core.federation import FederationScheduler
from repro.core.ppat import PPATConfig
from repro.kge.data import synthesize_universe


def main():
    kgs = synthesize_universe(
        seed=0,
        scale=1 / 400,
        kg_stats=[("Books", 12, 100000, 340000), ("Movies", 10, 80000, 270000)],
        alignments=[("Books", "Movies", 30000)],
    )
    for name, kg in kgs.items():
        print(f"{name}: {kg.num_entities} entities, {len(kg.triples)} triples")

    fed = FederationScheduler(
        kgs,
        dim=32,
        ppat_cfg=PPATConfig(steps=150, seed=0),
        local_epochs=150,
        update_epochs=40,
        seed=0,
    )
    init = fed.initial_training()
    print("\nafter local training :", {k: round(v, 3) for k, v in init.items()})

    final = fed.run(max_ticks=3)
    print("after federation     :", {k: round(v, 3) for k, v in final.items()})

    for ev in fed.events:
        if ev.kind == "ppat":
            arrow = "✓ kept" if ev.accepted else "✗ backtracked"
            print(
                f"  PPAT({ev.client}→{ev.host}): {ev.score_before:.3f} → "
                f"{ev.score_after:.3f} {arrow}  (ε̂={ev.epsilon:.1f})"
            )
    print(f"\nprivacy: per-handshake ε̂ from the moments accountant above; "
          f"paper setting λ={fed.ppat_cfg.lam}, δ={fed.ppat_cfg.delta}")


if __name__ == "__main__":
    main()
