"""End-to-end driver: train a ~100M-param dense LM for a few hundred steps.

Uses the real qwen3-0.6b layer stack at a width that lands near 100M params
(the full 0.6B card at vocab 152k would be embedding-dominated on CPU), the
synthetic corpus, AdamW + cosine, checkpointing — the whole substrate.

  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.checkpoint import save_checkpoint
from repro.configs import TrainConfig, get_config
from repro.data.pipeline import SyntheticTextDataset, make_batches
from repro.train.step import init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--checkpoint", default="/tmp/train_lm_100m.npz")
    args = ap.parse_args()

    # qwen3 family, sized to ~100M params
    cfg = get_config("qwen3-0.6b").replace(
        num_layers=8, d_model=512, num_heads=8, num_kv_heads=4, head_dim=64,
        d_ff=2048, vocab_size=32768, dtype="float32",
    )
    n = cfg.param_count()
    print(f"model: {cfg.num_layers}L d={cfg.d_model} vocab={cfg.vocab_size} "
          f"→ {n/1e6:.1f}M params")

    tcfg = TrainConfig(
        global_batch=args.batch, seq_len=args.seq_len, microbatches=1,
        ce_chunk=1024, learning_rate=1e-3,
        warmup_steps=20, total_steps=args.steps,
    )
    state = init_train_state(jax.random.PRNGKey(0), cfg)
    step = jax.jit(make_train_step(cfg, tcfg))
    ds = SyntheticTextDataset(vocab_size=cfg.vocab_size, seed=0)

    t0 = time.time()
    first = None
    for i, batch in enumerate(
        make_batches(ds, batch=args.batch, seq_len=args.seq_len, steps=args.steps)
    ):
        state, m = step(state, {k: jnp.asarray(v) for k, v in batch.items()})
        loss = float(m["loss"])
        first = first if first is not None else loss
        if (i + 1) % 20 == 0:
            tps = args.batch * args.seq_len * 20 / (time.time() - t0)
            t0 = time.time()
            print(f"step {i+1:4d} loss={loss:.4f} lr={float(m['lr']):.2e} tok/s={tps:,.0f}")
    print(f"\nloss: {first:.3f} → {loss:.3f} over {args.steps} steps")
    save_checkpoint(args.checkpoint, state.params,
                    metadata={"arch": "qwen3-100m", "steps": args.steps})
    print(f"checkpoint: {args.checkpoint}")


if __name__ == "__main__":
    main()
